"""repro.sim.energy + Trace aggregate tests (ISSUE-3).

Covers the trace-reduction edge cases (empty trace, predicate filtering,
untagged events, cache invalidation) and the energy-model invariants the
issue pins: energy is monotone in bytes moved, ping-pong never increases
EDP at fixed shape, the three-way energy ordering matches the paper's
efficiency claims, and the calibrated model agrees with the napkin
constants the roofline benchmarks alias.
"""
import os
import sys

import pytest

from repro.configs import registry
from repro.configs.hardware import STREAMDCIM_BASE, HardwareConfig
from repro.core.types import ExecutionMode
from repro.sim import (ENERGY_PRESETS, EnergyModel, STREAMDCIM_ENERGY_BASE,
                       compare_modes, energy_of_trace, simulate_plan)
from repro.sim.trace import Event, Trace

EM = ExecutionMode
SEQ = 1024          # short sequences keep the simulated points fast


def _trace(events):
    tr = Trace()
    for e in events:
        tr.add(e)
    return tr


# ------------------------------------------------------------- trace edges

def test_empty_trace_reductions():
    tr = Trace()
    assert tr.makespan == 0
    assert tr.utilization("ATTN") == 0.0
    assert tr.rewrite_stall_fraction() == 0.0
    assert tr.bytes_moved("HBM") == 0
    assert tr.dma_bytes_by_op() == {}
    assert tr.utilizations() == {}
    assert tr.summary()["makespan_cycles"] == 0.0
    rep = energy_of_trace(tr, STREAMDCIM_BASE)
    assert rep.total_pj == 0.0 and rep.edp == 0.0


def test_bytes_moved_predicate_filtering():
    tr = _trace([
        Event(0, "dma", "HBM", 0, 10, bytes=100, tag="a:xdma"),
        Event(1, "dma", "HBM", 10, 20, bytes=50, tag="b:qdma"),
        Event(2, "forward", "NOC", 0, 5, bytes=999, tag="a:fwd"),
    ])
    assert tr.bytes_moved("HBM") == 150
    assert tr.bytes_moved("HBM", pred=lambda e: e.op == "a") == 100
    assert tr.bytes_moved("NOC") == 999
    assert tr.bytes_moved("BUS") == 0


def test_dma_bytes_by_op_untagged_events():
    tr = _trace([
        Event(0, "dma", "HBM", 0, 10, bytes=100, tag="a:xdma"),
        Event(1, "dma", "HBM", 10, 20, bytes=7),            # untagged
    ])
    by_op = tr.dma_bytes_by_op()
    assert by_op["a"] == 100
    assert by_op[""] == 7           # untagged bytes keep their own bucket
    assert sum(by_op.values()) == tr.bytes_moved("HBM")


def test_trace_cache_invalidated_on_add():
    tr = _trace([Event(0, "compute", "ATTN", 0, 10)])
    assert tr.busy_cycles("ATTN") == 10 and tr.makespan == 10
    tr.add(Event(1, "compute", "ATTN", 10, 30))
    assert tr.busy_cycles("ATTN") == 30 and tr.makespan == 30
    tr.events.append(Event(2, "compute", "GEN", 0, 5))    # direct append
    assert tr.busy_cycles("GEN") == 5


def test_cached_summary_matches_event_scan():
    res = compare_modes(registry.get_config("vilbert-base"),
                        STREAMDCIM_BASE, seq_len=SEQ)[EM.TILE_STREAM]
    tr = res.trace
    for r in ("GEN", "ATTN", "HBM", "NOC", "BUS"):
        assert tr.busy_cycles(r) == sum(
            e.cycles for e in tr.events if e.resource == r)
        assert tr.bytes_moved(r) == sum(
            e.bytes for e in tr.events if e.resource == r)
    assert tr.makespan == max(e.end for e in tr.events)


# --------------------------------------------------------- energy invariants

def test_energy_monotone_in_bytes_moved():
    base = [Event(0, "dma", "HBM", 0, 10, bytes=100, tag="a:xdma"),
            Event(1, "forward", "NOC", 0, 10, bytes=64, tag="a:fwd"),
            Event(2, "rewrite", "BUS", 0, 10, bytes=64, tag="a:rw")]
    lo = energy_of_trace(_trace(base), STREAMDCIM_BASE)
    for i in range(3):
        more = [Event(e.task_id, e.kind, e.resource, e.start, e.end,
                      e.bytes + (512 if j == i else 0), e.tag)
                for j, e in enumerate(base)]
        hi = energy_of_trace(_trace(more), STREAMDCIM_BASE)
        assert hi.total_pj > lo.total_pj, f"event {i} bytes not charged"
        assert hi.dynamic_pj > lo.dynamic_pj


def test_energy_breakdown_sums_to_total():
    res = compare_modes(registry.get_config("vilbert-base"),
                        STREAMDCIM_BASE, seq_len=SEQ)[EM.TILE_STREAM]
    rep = res.energy()
    assert sum(rep.by_resource.values()) == pytest.approx(rep.total_pj)
    # per-op breakdown covers all dynamic energy (leakage unattributed)
    assert sum(rep.by_op.values()) == pytest.approx(rep.dynamic_pj)
    assert rep.total_pj == rep.dynamic_pj + rep.leakage_pj
    assert rep.edp == pytest.approx(rep.total_pj * res.cycles)


def test_three_way_energy_ordering_matches_paper():
    """Paper §IV efficiency claim: StreamDCIM beats layer-based beats
    non-streaming on energy for the MHA models, under every preset."""
    res = compare_modes(registry.get_config("vilbert-base"),
                        STREAMDCIM_BASE, seq_len=SEQ)
    for em in ENERGY_PRESETS.values():
        e = {m: r.energy(em).total_pj for m, r in res.items()}
        assert e[EM.TILE_STREAM] < e[EM.LAYER_STREAM] < e[EM.NON_STREAM], em.name
        d = {m: r.energy(em).edp for m, r in res.items()}
        assert d[EM.TILE_STREAM] < d[EM.LAYER_STREAM] < d[EM.NON_STREAM], em.name


def test_ping_pong_never_increases_edp_at_fixed_shape():
    from repro.plan import plan_model
    cfg = registry.get_config("vilbert-base")
    for bus in (512, 2048):
        pp = HardwareConfig.sweep(rewrite_bus_bits=bus, ping_pong=True)
        nopp = HardwareConfig.sweep(rewrite_bus_bits=bus, ping_pong=False)
        r_pp = simulate_plan(plan_model(cfg, hw=pp, seq_len=SEQ), hw=pp)
        r_no = simulate_plan(plan_model(cfg, hw=nopp, seq_len=SEQ), hw=nopp)
        assert r_pp.edp() <= r_no.edp(), f"bus={bus}"
        assert r_pp.cycles <= r_no.cycles


def test_rewrite_events_carry_bytes():
    res = compare_modes(registry.get_config("vilbert-base"),
                        STREAMDCIM_BASE, seq_len=SEQ)
    for r in res.values():
        rewrites = [e for e in r.trace.events if e.kind == "rewrite"]
        assert rewrites and all(e.bytes > 0 for e in rewrites)


def test_byteless_rewrite_fallback_consistent_across_breakdowns():
    """Byte-less rewrite events (pre-PR-3 traces) are charged via the
    write-port width the cycles imply, identically in the per-resource
    and per-op breakdowns — even mixed with byte-carrying rewrites."""
    hw = STREAMDCIM_BASE
    tr = _trace([
        Event(0, "rewrite", "BUS", 0, 10, bytes=0, tag="a:rw"),   # legacy
        Event(1, "rewrite", "BUS", 10, 20, bytes=64, tag="b:rw"),
    ])
    rep = energy_of_trace(tr, hw)
    em = STREAMDCIM_ENERGY_BASE
    expect_a = 10 * hw.rewrite_bytes_per_cycle * em.pj_per_rewrite_byte
    expect_b = 64 * em.pj_per_rewrite_byte
    assert rep.by_op["a"] == pytest.approx(expect_a)
    assert rep.by_op["b"] == pytest.approx(expect_b)
    assert rep.dynamic_pj == pytest.approx(expect_a + expect_b)
    assert sum(rep.by_op.values()) == pytest.approx(rep.dynamic_pj)


def test_energy_model_validation():
    with pytest.raises(ValueError, match="pj_per_hbm_byte"):
        EnergyModel(pj_per_hbm_byte=-1.0)
    with pytest.raises(ValueError, match="leakage"):
        EnergyModel(leak_pj_per_cycle={"GEN": -0.1})


# ----------------------------------------------------- napkin cross-check

def test_calibration_matches_napkin_constants():
    """The benchmarks' joule-per-unit napkin names are aliases over the
    calibrated model (satellite: duplicate constants retired)."""
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks import common
    em = STREAMDCIM_ENERGY_BASE
    assert common.E_HBM_PER_BYTE == pytest.approx(em.pj_per_hbm_byte * 1e-12)
    assert common.E_VMEM_PER_BYTE == pytest.approx(em.pj_per_noc_byte * 1e-12)
    assert common.E_PER_FLOP == pytest.approx(em.pj_per_flop * 1e-12)
    # sanity anchors: HBM ~5.6 pJ/bit, on-chip ~2 pJ/byte
    assert 20 <= em.pj_per_hbm_byte <= 100
    assert em.pj_per_noc_byte < em.pj_per_rewrite_byte < em.pj_per_hbm_byte
    # CIM INT8 MACs must be cheaper per op than the napkin bf16 MXU flop
    assert (em.pj_per_macro_cycle
            / em.macro_ops_per_cycle(STREAMDCIM_BASE)) < em.pj_per_flop


def test_registry_exposes_energy_models():
    assert registry.get_energy_model(
        "streamdcim-energy-base") is STREAMDCIM_ENERGY_BASE
    assert set(registry.ENERGY_CONFIGS) == set(ENERGY_PRESETS)
