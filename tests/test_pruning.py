"""DTPU token-pruning invariants — hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, st

from repro.core import pruning as P
from repro.core.types import PruningConfig

KEYS = jax.random.split(jax.random.PRNGKey(11), 4)


@given(seq=st.integers(8, 256), layers=st.integers(1, 16))
@settings(max_examples=25, deadline=None)
def test_keep_plan_monotone_and_bounded(seq, layers):
    cfg = PruningConfig(enabled=True, min_tokens=4)
    plan = P.keep_plan(cfg, layers, seq)
    assert len(plan) == layers
    assert all(plan[i] >= plan[i + 1] for i in range(layers - 1))
    assert all(4 <= n <= seq for n in plan)


@given(b=st.integers(1, 4), s=st.integers(8, 64),
       keep_frac=st.floats(0.2, 1.0))
@settings(max_examples=25, deadline=None)
def test_select_tokens_topk_and_sorted(b, s, keep_frac):
    keep = max(int(s * keep_frac), 1)
    scores = jax.random.uniform(KEYS[0], (b, s))
    idx = P.select_tokens(scores, keep)
    assert idx.shape == (b, keep)
    idx_np = np.asarray(idx)
    # order-preserving (ascending) and unique
    for row in idx_np:
        assert (np.diff(row) > 0).all()
    # top-k by score: min kept score >= max dropped score
    sc = np.asarray(scores)
    for i in range(b):
        kept = set(idx_np[i].tolist())
        dropped = [sc[i, j] for j in range(s) if j not in kept]
        if dropped:
            assert sc[i][idx_np[i]].min() >= max(dropped) - 1e-6


def test_scores_are_attention_column_means():
    B, Hq, Hkv, Sq, Sk, hd = 2, 4, 2, 32, 48, 16
    q = jax.random.normal(KEYS[1], (B, Hq, Sq, hd))
    k = jax.random.normal(KEYS[2], (B, Hkv, Sk, hd))
    s = P.attention_column_scores(q, k)
    _, s_ref = ref_scores(q, k)
    np.testing.assert_allclose(s, s_ref, atol=1e-5, rtol=1e-5)


def ref_scores(q, k):
    from repro.kernels import ref
    return ref.ref_attention(q, k,
                             jnp.zeros_like(k), return_scores=True)


def test_strided_scoring_preserves_ranking():
    """The DTPU's subsampled scoring pass must rank tokens ~like the full
    pass.  Uses structured keys (a subset of genuinely attention-attracting
    tokens, as in real attention maps) — on iid noise the column means are
    indistinguishable and ranking is meaningless for both passes."""
    B, Hq, Hkv, Sq, Sk, hd = 1, 4, 2, 256, 256, 32
    u = jnp.zeros((hd,)).at[0].set(1.0)            # shared bias direction
    q = jax.random.normal(KEYS[1], (B, Hq, Sq, hd)) + 1.5 * u
    k = jax.random.normal(KEYS[2], (B, Hkv, Sk, hd)) * 0.3
    # make 32 tokens systematically attractive (aligned with the bias)
    hot = jnp.arange(0, Sk, 8)
    k = k.at[:, :, hot, :].add(2.0 * u)
    full = P.attention_column_scores(q, k)
    strided = P.attention_column_scores(q, k, sample_stride=8)
    keep = len(hot)
    top_full = set(np.asarray(P.select_tokens(full, keep))[0].tolist())
    top_strided = set(np.asarray(P.select_tokens(strided, keep))[0].tolist())
    overlap = len(top_full & top_strided) / keep
    assert overlap > 0.8, overlap


def test_prune_stream_gathers_consistently():
    B, S, D = 2, 32, 8
    x = jax.random.normal(KEYS[3], (B, S, D))
    scores = jax.random.uniform(KEYS[0], (B, S))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    kept, idx, pos_kept = P.prune_stream(x, scores, 10, positions=pos)
    assert kept.shape == (B, 10, D)
    np.testing.assert_array_equal(np.asarray(pos_kept), np.asarray(idx))
    for b in range(B):
        np.testing.assert_allclose(np.asarray(kept[b]),
                                   np.asarray(x[b][np.asarray(idx[b])]))


def test_compute_savings_math():
    plan = (64, 32, 16)
    frac = P.pruning_compute_savings(plan, 64)
    expect = (64 ** 2 + 32 ** 2 + 16 ** 2) / (3 * 64 ** 2)
    assert abs(frac - expect) < 1e-9
