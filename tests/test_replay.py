"""Plan/trace replay + calibration (ISSUE-4 acceptance, DESIGN.md §10).

Pins the whole record→attach→replay→fit pipeline:

* ``KernelTrace`` / ``CalibrationReport`` JSON round-trips;
* trace attachment by op name (mismatches rejected, kernel-level
  sub-records ignored, mode overrides drop stale traces);
* the mixed-plan replay contract — a traced op replayed through
  ``simulate_plan`` reproduces its recorded per-op timing and bytes
  *exactly* while untraced ops keep the analytic lowering unchanged;
* ExecutionPlan JSON round-trip *with attached traces*: round-trip then
  replay reproduces per-op cycles and energy exactly (mirroring the DSE
  frontier-replay test);
* live recording through the instrumented kernel paths
  (``attention_by_plan``, ``tile_gemm``, ``stream_attention``) on CPU;
* calibration fitting and the ``repro.dse`` calibration axis.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.core.types import ExecutionMode
from repro.plan import plan_model
from repro.plan.planner import ExecutionPlan
from repro.sim import simulate_plan
from repro.sim.replay import (CalibrationReport, KernelRecorder,
                              KernelTrace, active_recorder,
                              analytic_op_profile, fit_calibration,
                              record_plan, recording)

SEQ = 256           # one tile block — small plans, real kernel shapes


@pytest.fixture(scope="module")
def plan():
    return plan_model(registry.get_config("vilbert-base"), seq_len=SEQ)


def _trace_for(lp, cycles=10_000, nbytes=4096, kind="attention"):
    return KernelTrace(op=lp.name, kind=kind, mode=lp.mode.value,
                       grid=(1, 1, 1), block_q=getattr(lp, "block_q", 256),
                       block_kv=getattr(lp, "block_kv", 256),
                       cycles=cycles, hbm_bytes=nbytes, source="manual")


def _op_events(res, name):
    return [e for e in res.trace.events if e.op == name]


def _op_span(res, name):
    evs = _op_events(res, name)
    return max(e.end for e in evs) - min(e.start for e in evs)


def _op_busy(res, name):
    busy = {}
    for e in _op_events(res, name):
        busy[e.resource] = busy.get(e.resource, 0) + e.cycles
    return busy


# ------------------------------------------------------------- KernelTrace

def test_kernel_trace_round_trips_and_validates():
    kt = KernelTrace(op="a", kind="gemm", mode="tile_stream", grid=(2, 3, 4),
                     cycles=77, hbm_bytes=123, block_q=128, block_kv=256,
                     wall_time_s=1.5e-3, flops=999)
    back = KernelTrace.from_dict(json.loads(json.dumps(kt.to_dict())))
    assert back == kt
    assert back.grid == (2, 3, 4)
    with pytest.raises(ValueError, match="kind"):
        dataclasses.replace(kt, kind="conv")
    with pytest.raises(ValueError, match="cycles"):
        dataclasses.replace(kt, cycles=0)
    with pytest.raises(ValueError, match="version"):
        KernelTrace.from_dict({**kt.to_dict(), "version": 99})


def test_trace_resource_follows_op_class():
    kt = KernelTrace(op="x", kind="attention", mode="tile_stream",
                     grid=(1,), block_q=1, block_kv=1, cycles=1,
                     hbm_bytes=0, source="manual")
    assert kt.resource == "ATTN"
    assert dataclasses.replace(kt, kind="gemm").resource == "GEN"


# -------------------------------------------------------------- attachment

def test_attach_traces_by_name_ignores_kernel_level_records(plan):
    lp = plan.layers[0]
    kt = _trace_for(lp)
    sub = dataclasses.replace(kt, op=f"{lp.name}/stream_attention")
    traced = plan.attach_traces([kt, sub])
    assert traced.traced_ops == (lp.name,)
    assert traced.layers[0].trace == kt
    assert traced.summary()["traced_ops"] == 1
    assert plan.summary()["traced_ops"] == 0     # original untouched


def test_attach_trace_rejects_wrong_op(plan):
    with pytest.raises(ValueError, match="cannot attach"):
        plan.layers[1].attach_trace(_trace_for(plan.layers[0]))


def test_without_traces_drops_everything(plan):
    traced = plan.attach_traces([_trace_for(lp) for lp in plan.layers[:3]])
    assert len(traced.traced_ops) == 3
    assert traced.without_traces().traced_ops == ()


def test_mode_override_drops_stale_trace(plan):
    lp0, lp1 = plan.layers[0], plan.layers[1]
    traced = plan.attach_traces([_trace_for(lp0), _trace_for(lp1)])
    het = traced.with_layer_modes({lp0.name: ExecutionMode.NON_STREAM})
    assert het.layer(lp0.name).trace is None      # recorded mode changed
    assert het.layer(lp1.name).trace is not None  # untouched layer keeps it


# ------------------------------------------------------- mixed-plan replay

def test_mixed_plan_replays_traced_ops_exactly(plan):
    """The acceptance criterion: traced ops reproduce recorded per-op
    timing and bytes exactly; untraced ops fall back to analytic lowering
    with identical per-op schedules — both in ONE plan."""
    lp0, lp1 = plan.layers[0], plan.layers[1]
    g0 = plan.gemms[0]
    traces = [_trace_for(lp0, cycles=31_415, nbytes=2_718),
              _trace_for(g0, cycles=141, nbytes=59, kind="gemm")]
    traced = plan.attach_traces(traces)
    analytic = simulate_plan(plan)
    mixed = simulate_plan(traced)

    assert analytic.replayed_ops == 0
    assert mixed.replayed_ops == 2
    # Replayed ops: recorded timing/bytes verbatim, on the op class's
    # macro resource.
    assert _op_span(mixed, lp0.name) == 31_415
    assert mixed.op_dma_bytes(lp0.name) == 2_718
    assert _op_busy(mixed, lp0.name) == {"ATTN": 31_415, "HBM": 0}
    assert _op_span(mixed, g0.name) == 141
    assert _op_busy(mixed, g0.name) == {"GEN": 141, "HBM": 0}
    # Untraced ops: the analytic schedule, unchanged event for event.
    assert _op_busy(mixed, lp1.name) == _op_busy(analytic, lp1.name)
    assert _op_span(mixed, lp1.name) == _op_span(analytic, lp1.name)
    assert mixed.op_dma_bytes(lp1.name) == analytic.op_dma_bytes(lp1.name)
    # Total = analytic total shifted by the replayed ops' deltas.
    delta = (31_415 - _op_span(analytic, lp0.name)
             + 141 - _op_span(analytic, g0.name))
    assert mixed.cycles == analytic.cycles + delta


def test_replay_flag_forces_analytic_lowering(plan):
    traced = plan.attach_traces([_trace_for(plan.layers[0])])
    assert simulate_plan(traced, replay=False).cycles \
        == simulate_plan(plan).cycles
    assert simulate_plan(traced, replay=False).replayed_ops == 0


def test_json_round_trip_with_traces_replays_exactly(plan):
    """ISSUE-4 satellite: plan -> to_json -> from_json -> simulate_plan
    reproduces per-op cycles AND energy exactly (the DSE frontier-replay
    guarantee extended to traced plans)."""
    traces = [_trace_for(lp, cycles=1000 + 7 * i, nbytes=100 + i)
              for i, lp in enumerate(plan.layers[:4])]
    traces.append(_trace_for(plan.gemms[0], cycles=777, nbytes=31,
                             kind="gemm"))
    traced = plan.attach_traces(traces)
    back = ExecutionPlan.from_json(traced.to_json())
    assert back == traced                       # traces round-trip exactly

    res0, res1 = simulate_plan(traced), simulate_plan(back)
    assert res1.cycles == res0.cycles
    assert res1.hbm_bytes == res0.hbm_bytes
    assert res1.replayed_ops == res0.replayed_ops == 5
    for kt in traces:
        assert _op_span(res1, kt.op) == kt.cycles
        assert res1.op_dma_bytes(kt.op) == kt.hbm_bytes
    e0, e1 = res0.energy(), res1.energy()
    assert e1.total_pj == e0.total_pj
    assert e1.by_op == e0.by_op


# ---------------------------------------------------------- live recording

def test_record_plan_records_and_attaches(plan):
    traced, rec = record_plan(plan, max_ops=2, iters=1, warmup=0)
    assert len(traced.traced_ops) == 2
    for kt in (traced.layers[0].trace, traced.layers[1].trace):
        assert kt.kind == "attention"
        assert kt.cycles > 0 and kt.wall_time_s > 0
        assert kt.source == "wall_time"
        assert kt.mode == "tile_stream"
        # grid: (batch, ceil(Sq/bq), ceil(Skv/bkv)) at the plan geometry
        assert kt.grid == (1, 1, 1) and kt.block_q == SEQ
        assert kt.hbm_bytes > 0
    res = simulate_plan(traced)
    assert res.replayed_ops == 2
    assert _op_span(res, traced.traced_ops[0]) \
        == traced.layers[0].trace.cycles


def test_record_plan_gemm_selection():
    plan = plan_model(registry.get_config("vilbert-base"), seq_len=SEQ)
    g = plan.gemms[0]
    traced, rec = record_plan(plan, ops=[g.name], iters=1, warmup=0)
    assert traced.traced_ops == (g.name,)
    kt = traced.gemms[0].trace
    assert kt.kind == "gemm"
    assert kt.flops == 2 * g.m * g.k * g.n
    # grid/tiling mirror the tile_gemm launch at its default blocks, and
    # bytes follow the kernel-level x + w + out convention.
    bm, bn, bk = min(256, g.m), min(256, g.n), min(512, g.k)
    assert kt.grid == (-(-g.n // bn), -(-g.m // bm), -(-g.k // bk))
    assert kt.block_q == bm and kt.block_kv == bn
    assert kt.hbm_bytes == 4 * (g.m * g.k + g.k * g.n + g.m * g.n)


def test_recorder_inactive_outside_block():
    assert active_recorder() is None
    with recording() as rec:
        assert active_recorder() is rec
    assert active_recorder() is None


def test_attention_by_plan_not_recorded_under_jit(plan):
    from repro.kernels import ops
    lp = plan.layers[0]
    q = jnp.ones((1, 2, 8, 16))
    x = jnp.ones((1, 8, 32))
    wk = jnp.ones((32, 2, 16)) * 0.1
    wv = jnp.ones((32, 2, 16)) * 0.1
    with recording() as rec:
        jax.jit(lambda *a: ops.attention_by_plan(lp, *a))(q, x, wk, wv)
    assert rec.records == []                    # tracers: nothing to time
    with recording() as rec:
        ops.attention_by_plan(lp, q, x, wk, wv)
    assert [t.op for t in rec.records] == [lp.name]


def test_tile_gemm_kernel_level_instrumentation():
    from repro.kernels.tile_gemm import tile_gemm
    x = jnp.ones((128, 128), jnp.float32)
    w = jnp.ones((128, 128), jnp.float32)
    with recording(KernelRecorder(iters=1, warmup=0)) as rec:
        tile_gemm(x, w, block_m=128, block_n=128, block_k=128,
                  interpret=True)
        with rec.label("ffn_up"):
            tile_gemm(x, w, block_m=64, block_n=64, block_k=64,
                      interpret=True)
    assert [t.op for t in rec.records] == ["tile_gemm", "ffn_up/tile_gemm"]
    assert rec.records[0].grid == (1, 1, 1)
    assert rec.records[1].grid == (2, 2, 2)
    assert rec.records[1].block_q == 64
    assert all(t.kind == "gemm" and t.cycles > 0 for t in rec.records)


def test_stream_attention_kernel_level_instrumentation():
    from repro.kernels.stream_attention import stream_attention
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 2, 128, 128))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 128, 128))
    wk = jax.random.normal(jax.random.PRNGKey(2), (128, 2, 128)) * 0.1
    wv = jax.random.normal(jax.random.PRNGKey(3), (128, 2, 128)) * 0.1
    with recording(KernelRecorder(iters=1, warmup=0)) as rec:
        stream_attention(q, x, wk, wv, block_q=128, block_k=128,
                         interpret=True)
    (kt,) = rec.records
    assert kt.op == "stream_attention"
    assert kt.kind == "attention" and kt.mode == "tile_stream"
    assert kt.grid == (1, 1, 1) and kt.cycles > 0


def test_measure_suppresses_nested_kernel_records():
    from repro.kernels.tile_gemm import tile_gemm
    x = jnp.ones((64, 64), jnp.float32)
    with recording(KernelRecorder(iters=1, warmup=0)) as rec:
        rec.measure(lambda: tile_gemm(x, x, interpret=True),
                    op="outer", kind="gemm")
    assert [t.op for t in rec.records] == ["outer"]   # no inner tile_gemm


# -------------------------------------------------------------- calibration

def test_fit_calibration_identity_when_recorded_equals_analytic(plan):
    """Synthetic traces whose cycles equal the analytic per-op span: the
    fitted report shows ratio 1 / zero error, and the fitted per-resource
    scales leave the simulated latency (nearly) unchanged."""
    prof = analytic_op_profile(plan)
    names = [lp.name for lp in plan.layers[:3]]
    traced = plan.attach_traces(
        [_trace_for(lp, cycles=prof[lp.name]["span"])
         for lp in plan.layers[:3]])
    rep = fit_calibration(traced)
    assert rep.traced_ops == 3
    assert rep.per_class["attention"]["mean_abs_rel_err"] == 0.0
    assert rep.ratio("attention") == 1.0
    base = simulate_plan(plan).cycles
    calibrated = simulate_plan(plan, calibration=rep).cycles
    assert abs(calibrated - base) / base < 0.05
    assert names  # (silences linters; names used for readability above)


def test_fit_calibration_requires_traces(plan):
    with pytest.raises(ValueError, match="no attached KernelTrace"):
        fit_calibration(plan)


def test_calibration_report_json_round_trip(plan):
    traced, _ = record_plan(plan, max_ops=1, iters=1, warmup=0)
    rep = fit_calibration(traced)
    back = CalibrationReport.from_json(rep.to_json())
    assert back.to_dict() == rep.to_dict()
    assert back.scale == rep.scale
    with pytest.raises(ValueError, match="version"):
        CalibrationReport.from_dict({**rep.to_dict(), "version": 99})


def test_calibration_scales_analytic_timing(plan):
    base = simulate_plan(plan)
    same = simulate_plan(plan, calibration={"ATTN": 1.0, "HBM": 1.0})
    assert same.cycles == base.cycles
    slower = simulate_plan(plan, calibration={"ATTN": 2.0, "GEN": 2.0,
                                              "HBM": 2.0, "NOC": 2.0,
                                              "BUS": 2.0})
    assert slower.cycles > base.cycles
    # Replayed ops are recorded ground truth: calibration leaves them be.
    traced = plan.attach_traces([_trace_for(plan.layers[0], cycles=555)])
    scaled = simulate_plan(traced, calibration={"ATTN": 3.0})
    assert _op_span(scaled, plan.layers[0].name) == 555


def test_calibration_rejects_garbage(plan):
    with pytest.raises(TypeError, match="CalibrationReport"):
        simulate_plan(plan, calibration=42)
    with pytest.raises(ValueError, match="scale"):
        CalibrationReport(name="x", model="m", hw="h", clock_hz=1e9,
                          per_class={}, scale={"ATTN": -1.0})


# ----------------------------------------------------- dse calibration axis

def test_dse_calibration_axis_partitions_rows():
    from repro.dse import Axes, run_sweep, simulate_point
    from repro.configs.hardware import STREAMDCIM_BASE
    cfg = registry.get_config("whisper-base")
    cal = CalibrationReport(
        name="cal-test", model=cfg.name, hw="streamdcim-base",
        clock_hz=1e9, per_class={},
        scale={"ATTN": 2.0, "GEN": 2.0, "HBM": 2.0})

    row0 = simulate_point(cfg, STREAMDCIM_BASE, seq_len=SEQ)
    row1 = simulate_point(cfg, STREAMDCIM_BASE, seq_len=SEQ,
                          calibration=cal)
    assert row0.calibration == "analytic"
    assert row0.calibration_scale == {}
    assert row1.calibration == "cal-test"
    assert row1.latency_cycles > row0.latency_cycles
    assert "calibration" in row0.to_dict()
    # A calibrated row is reproducible from the artifact alone: replay
    # its plan_json under its recorded calibration_scale.
    replayed = simulate_plan(ExecutionPlan.from_json(row1.plan_json),
                             calibration=row1.calibration_scale)
    assert replayed.cycles == row1.latency_cycles
    # Distinct raw mappings get distinct labels (never one "custom" cell).
    rowa = simulate_point(cfg, STREAMDCIM_BASE, seq_len=SEQ,
                          calibration={"ATTN": 2.0})
    rowb = simulate_point(cfg, STREAMDCIM_BASE, seq_len=SEQ,
                          calibration={"ATTN": 8.0})
    assert rowa.calibration == "custom:ATTNx2"
    assert rowb.calibration == "custom:ATTNx8"
    assert rowa.calibration != rowb.calibration

    axes = Axes(groups=((2, 1), (4, 2)), rewrite_bus_bits=(512,),
                ping_pong=(True,))
    sweep = run_sweep(models=[cfg.name], axes=axes, seq_lens=(SEQ,),
                      include_presets=False, calibrations=(None, cal))
    assert sweep.calibrations() == ["analytic", "cal-test"]
    assert len(sweep.rows) == 4                  # 2 points x 2 calibrations
    # Frontier/knee extraction never mixes calibrations: each cell is
    # labeled, and analytic rows (always faster here) must not dominate
    # the calibrated cell away.
    pareto_a = sweep.pareto(cfg.name, SEQ, "analytic")
    pareto_c = sweep.pareto(cfg.name, SEQ, "cal-test")
    assert pareto_a and pareto_c
    assert all(r.calibration == "cal-test" for r in pareto_c)
    labels = set(sweep.knees())
    assert f"{cfg.name}+analytic" in labels
    assert f"{cfg.name}+cal-test" in labels
    assert set(sweep.to_dict()["pareto"]) == labels
