"""Tests for ``repro.obs`` — timelines, serving SLO metrics, attribution
(DESIGN.md §12) — plus the observability hooks in the engine, the serving
simulator, and the DSE sweep."""
from __future__ import annotations

import json

import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.core.types import ExecutionMode as EM
from repro.obs import attribution, metrics, timeline
from repro.obs.metrics import (MetricsRegistry, RequestSpan,
                               assert_serve_parity, percentile,
                               spans_from_steps, summarize, summarize_spans)
from repro.serve.engine import Engine, Request
from repro.serve.schedule import ServeRequest
from repro.sim import (rewrite_stall_trace, simulate_rewrite_stall,
                       simulate_serve)
from repro.sim.trace import Event, Trace

SMOKE = registry.get_config("starcoder2-7b", smoke=True)


def _params(cfg=SMOKE):
    mod = registry.model_module(cfg)
    return mod.init(jax.random.PRNGKey(0), cfg)


def _req(rid, plen, new, arr=0):
    return Request(rid=rid,
                   prompt=np.arange(1, plen + 1, dtype=np.int32),
                   max_new_tokens=new, arrival_step=arr)


def _sreq(rid, plen, new, arr=0):
    return ServeRequest(rid, plen, new, arr)


# ---------------------------------------------------------------------------
# metrics: percentiles / registry
# ---------------------------------------------------------------------------

def test_percentile_exact_interpolation():
    vals = [1.0, 2.0, 3.0, 4.0]
    assert percentile(vals, 0.0) == 1.0
    assert percentile(vals, 1.0) == 4.0
    assert percentile(vals, 0.5) == 2.5
    assert percentile([5.0], 0.99) == 5.0
    assert percentile([], 0.5) == 0.0          # empty sample: defined zero
    with pytest.raises(ValueError):
        percentile(vals, 1.5)


def test_summarize_empty_is_all_zeros():
    s = summarize([])
    assert s == {"count": 0.0, "mean": 0.0, "p50": 0.0, "p95": 0.0,
                 "p99": 0.0, "max": 0.0}


def test_registry_counter_gauge_histogram():
    reg = MetricsRegistry()
    reg.counter("reqs").inc()
    reg.counter("reqs").inc(2)                 # get-or-create: same counter
    reg.gauge("depth").set(7)
    for v in (1.0, 2.0, 3.0):
        reg.histogram("lat").observe(v)
    d = reg.to_dict()
    assert d["counters"]["reqs"] == 3
    assert d["gauges"]["depth"] == 7.0
    assert d["histograms"]["lat"]["p50"] == 2.0
    with pytest.raises(ValueError):
        reg.counter("reqs").inc(-1)            # counters only increase


def test_request_span_validation_and_derived_metrics():
    s = RequestSpan(rid=0, arrival=1.0, admit=3.0, first_token=4.0,
                    finish=9.0, tokens=6)
    assert s.queue_delay == 2.0
    assert s.ttft == 1.0                       # admit -> token1
    assert s.tpot == 1.0                       # mean inter-token gap
    assert s.e2e == 8.0
    single = RequestSpan(rid=1, arrival=0, admit=0, first_token=1,
                         finish=1, tokens=1)
    assert single.tpot == 0.0                  # no gaps exist
    with pytest.raises(ValueError):
        RequestSpan(rid=2, arrival=5, admit=3, first_token=4, finish=9,
                    tokens=2)                  # admit before arrival
    with pytest.raises(ValueError):
        RequestSpan(rid=3, arrival=0, admit=0, first_token=1, finish=1,
                    tokens=0)


# ---------------------------------------------------------------------------
# Event tag helpers (satellite: malformed tags)
# ---------------------------------------------------------------------------

def test_event_tag_helpers_malformed_tags():
    full = Event(0, "dma", "HBM", 0, 1, tag="cox0_co:xdma:q0k1")
    assert (full.op, full.kind_tag, full.tile) == ("cox0_co", "xdma", "q0k1")
    deep = Event(1, "dma", "HBM", 0, 1, tag="d0:s1:kvdma:k2")
    assert (deep.op, deep.kind_tag, deep.tile) == ("d0", "s1", "kvdma:k2")
    two = Event(2, "compute", "GEN", 0, 1, tag="ffn0:gemm")
    assert (two.op, two.kind_tag, two.tile) == ("ffn0", "gemm", "")
    raw = Event(3, "compute", "GEN", 0, 1, tag="justanop")
    assert (raw.op, raw.kind_tag, raw.tile) == ("justanop", "", "")
    empty = Event(4, "compute", "GEN", 0, 1, tag="")
    assert (empty.op, empty.kind_tag, empty.tile) == ("", "", "")


# ---------------------------------------------------------------------------
# attribution: the §I 57% number, bottlenecks, op classes
# ---------------------------------------------------------------------------

def test_attribution_reproduces_paper_57_percent():
    trace = rewrite_stall_trace()              # serial NON/LAYER-style trace
    rep = attribution.attribute(trace)
    assert rep.rewrite_stall_fraction == pytest.approx(4 / 7, abs=1e-9)
    # ... and agrees with both the trace reduction and the §I micro-sim.
    assert rep.rewrite_stall_fraction == pytest.approx(
        trace.rewrite_stall_fraction())
    assert rep.rewrite_stall_fraction == pytest.approx(
        simulate_rewrite_stall()["rewrite_frac"])
    assert rep.rewrite_overlapped == 0         # no shadow sub-array
    assert rep.critical_resource == "ATTN"
    assert rep.by_op_class["attention"].rewrite_stall_fraction == \
        pytest.approx(4 / 7, abs=1e-9)


def test_attribution_pingpong_rewrites_are_overlapped():
    rep = attribution.attribute(rewrite_stall_trace(ping_pong=True))
    assert rep.rewrite_exposed == 0            # all rewrites ride the bus
    assert rep.rewrite_overlapped > 0
    assert rep.rewrite_stall_fraction == 0.0


def test_op_class_strips_serve_framing():
    oc = attribution.op_class
    assert oc("t3.pre.r1.cox0_co") == "attention"
    assert oc("t4.dec.layer0.decode") == "decode"
    assert oc("d0.decode") == "decode"
    assert oc("ffn2") == "ffn"
    assert oc("t0.pre.r2.ffn1") == "ffn"
    assert oc("attn0_oproj") == "proj"
    assert oc("it3") == "attention"            # §I micro-workload phases
    assert oc("") == "attention"


def test_bottleneck_of_and_format_report():
    t = Trace()
    t.add(Event(0, "compute", "GEN", 0, 100, tag="a:gemm"))
    t.add(Event(1, "dma", "HBM", 0, 40, 512, tag="a:xdma"))
    assert attribution.bottleneck_of(t) == "GEN"
    text = attribution.format_report(attribution.attribute(t), title="x")
    assert "GEN" in text and "critical" in text
    assert attribution.bottleneck_of(Trace()) == ""


def test_sweep_row_has_bottleneck():
    from repro.dse.sweep import simulate_point
    hw = registry.get_hw_config("streamdcim-base")
    row = simulate_point(registry.get_config("vilbert-base"), hw, seq_len=64)
    assert row.bottleneck in row.utilization
    assert row.to_dict()["bottleneck"] == row.bottleneck


# ---------------------------------------------------------------------------
# timelines
# ---------------------------------------------------------------------------

def test_timeline_from_trace_and_validation():
    t = rewrite_stall_trace()
    tl = timeline.timeline_from_trace(t, title="stall")
    info = timeline.validate_timeline(tl)
    assert info["events"] == len(t.events)
    assert tl["otherData"]["schema_version"] == timeline.TIMELINE_SCHEMA_VERSION
    json.dumps(tl)                             # must serialize cleanly
    names = {e["args"]["name"] for e in tl["traceEvents"]
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert "ATTN" in names
    kinds = {e["cat"] for e in tl["traceEvents"] if e["ph"] == "X"}
    assert kinds == {"compute", "rewrite"}


def test_validate_timeline_rejects_garbage():
    with pytest.raises(ValueError):
        timeline.validate_timeline({"traceEvents": []})
    with pytest.raises(ValueError):
        timeline.validate_timeline({"traceEvents": [
            {"ph": "X", "ts": -1.0, "dur": 1.0, "pid": 1, "tid": 1}]})
    with pytest.raises(ValueError):            # non-monotone within a track
        timeline.validate_timeline({"traceEvents": [
            {"ph": "M", "pid": 1, "name": "process_name",
             "args": {"name": "p"}},
            {"ph": "X", "ts": 5.0, "dur": 1.0, "pid": 1, "tid": 1},
            {"ph": "X", "ts": 2.0, "dur": 1.0, "pid": 1, "tid": 1}]})


def test_timeline_from_serve_has_step_and_request_tracks():
    res = simulate_serve(SMOKE, [_sreq(0, 6, 3), _sreq(1, 9, 2, 1)], slots=2)
    tl = timeline.timeline_from_serve(res, title="serve")
    timeline.validate_timeline(tl)
    xs = [e for e in tl["traceEvents"] if e["ph"] == "X"]
    cats = {e["cat"] for e in xs}
    assert {"serve-step", "request"} <= cats
    steps = [e for e in xs if e["cat"] == "serve-step"]
    assert len(steps) == res.num_steps
    req = [e for e in xs if e["cat"] == "request"]
    assert any(e["name"].endswith(":prefill") for e in req)
    assert any(e["name"].endswith(":decode") for e in req)
    # request lifecycle slices carry the cycle-domain TTFT
    assert all("ttft_cycles" in e["args"] for e in req)


def test_timeline_from_records_kernels_track(tmp_path):
    from repro.sim.replay import KernelTrace
    recs = [KernelTrace(op="attn0", kind="attention", mode="tile_stream",
                        grid=(1, 2), block_q=64, block_kv=64,
                        wall_time_s=1e-3, cycles=1000, hbm_bytes=4096,
                        flops=1 << 20),
            KernelTrace(op="ffn0", kind="gemm", mode="tile_stream",
                        grid=(4,), block_q=0, block_kv=0,
                        wall_time_s=2e-3, cycles=2000, hbm_bytes=8192,
                        flops=1 << 21)]
    tl = timeline.timeline_from_records(recs, title="kernels")
    timeline.validate_timeline(tl)
    xs = [e for e in tl["traceEvents"] if e["ph"] == "X"]
    assert [e["ts"] for e in xs] == [0.0, 1000.0]   # laid out end-to-end
    path = timeline.write_timeline(tl, str(tmp_path / "k.perfetto.json"))
    assert timeline.validate_timeline(timeline.load_timeline(path))


# ---------------------------------------------------------------------------
# serving SLO metrics: simulator side
# ---------------------------------------------------------------------------

def test_simulate_serve_metrics_staggered():
    reqs = [_sreq(0, 6, 4, 0), _sreq(1, 9, 3, 1), _sreq(2, 5, 5, 3)]
    res = simulate_serve(SMOKE, reqs, slots=2)
    m = res.metrics
    assert m["requests"] == 3
    assert m["tokens"] == 4 + 3 + 5
    assert m["ttft"]["max"] == 1.0             # token1 lands at admit step end
    spans = res.request_spans
    assert [s.tokens for s in spans] == [4, 3, 5]
    # queue delay: rid2 arrives step 3; both slots busy until rid1 finishes
    by_rid = {s.rid: s for s in spans}
    assert by_rid[0].queue_delay == 0.0
    assert by_rid[2].admit >= 3.0
    # cycle-domain spans live on the same schedule, in simulated cycles
    cyc = {s.rid: s for s in res.cycle_spans}
    assert set(cyc) == set(by_rid)
    for rid, s in cyc.items():
        assert s.unit == "cycles"
        assert s.ttft > 1.0                    # real prefill latency
        assert s.finish <= res.cycles
    assert res.cycle_metrics["tpot"]["p50"] > 0
    # the registry recorded both domains
    h = res.registry.to_dict()["histograms"]
    assert h["steps.ttft"]["count"] == 3
    assert h["cycles.ttft"]["count"] == 3


def test_simulate_serve_zero_requests_well_defined():
    res = simulate_serve(SMOKE, [], slots=2)
    assert res.num_steps == 0 and res.cycles == 0
    m = res.metrics
    assert m["requests"] == 0 and m["tokens"] == 0
    for metric in metrics.SPAN_METRICS:
        assert m[metric]["count"] == 0.0
        assert m[metric]["p99"] == 0.0
    assert res.cycle_spans == []
    json.dumps(res.to_dict())                  # artifact serializes


def test_simulate_serve_single_request_degenerate():
    res = simulate_serve(SMOKE, [_sreq(0, 6, 1)], slots=2)
    m = res.metrics
    assert m["requests"] == 1 and m["tokens"] == 1
    assert m["tpot"]["max"] == 0.0             # one token: no gaps
    assert m["e2e"]["p50"] == 1.0
    (span,) = res.cycle_spans
    assert span.tokens == 1 and span.tpot == 0.0
    assert span.first_token == span.finish == res.cycles


# ---------------------------------------------------------------------------
# engine==sim parity (satellite: all three modes, staggered, degenerate)
# ---------------------------------------------------------------------------

def test_assert_serve_parity_catches_divergence():
    res = simulate_serve(SMOKE, [_sreq(0, 6, 3)], slots=1)
    good = res.metrics
    assert_serve_parity(good, good)            # self-parity holds
    bad = dict(good)
    bad["tokens"] = good["tokens"] + 1
    with pytest.raises(AssertionError, match="tokens"):
        assert_serve_parity(bad, good)
    bad = dict(good)
    bad["ttft"] = dict(good["ttft"], p99=123.0)
    with pytest.raises(AssertionError, match="ttft"):
        assert_serve_parity(bad, good)
    with pytest.raises(AssertionError, match="missing"):
        assert_serve_parity({"requests": 1, "tokens": 3}, good)


class _FakeClock:
    """Deterministic ``time.perf_counter`` stand-in: each call advances a
    fixed tick, so wall-domain percentiles stop depending on host speed
    (CI boxes were flaking the ``> 0`` assertions on coarse clocks)."""

    def __init__(self, dt: float = 0.125) -> None:
        self.t = 0.0
        self.dt = dt

    def __call__(self) -> float:
        self.t += self.dt
        return self.t


@pytest.mark.parametrize("mode", [None, EM.TILE_STREAM, EM.LAYER_STREAM,
                                  EM.NON_STREAM])
def test_engine_sim_slo_parity_across_modes(mode):
    params = _params()
    kw = {} if mode is None else {"mode": mode}
    eng = Engine(SMOKE, params, slots=2, max_len=64, clock=_FakeClock(),
                 **kw)
    traffic = [(6, 4, 0), (9, 3, 1), (5, 5, 3), (4, 2, 3)]
    for rid, (p, n, a) in enumerate(traffic):
        eng.submit(_req(rid, p, n, a))
    eng.run()
    stats = eng.stats()
    res = simulate_serve(SMOKE,
                         [_sreq(rid, p, n, a)
                          for rid, (p, n, a) in enumerate(traffic)],
                         slots=2, mode=mode, force_mode=mode is not None)
    assert_serve_parity(stats, res.metrics)
    assert stats["requests"] == len(traffic)
    # wall-clock spans exist and share the request population; with the
    # injected clock the strictly-positive TTFT is guaranteed, not a
    # host-speed accident.
    assert stats["wall"]["requests"] == len(traffic)
    assert stats["wall"]["ttft"]["p50"] > 0
    assert stats["metrics"]["histograms"]["wall.ttft"]["count"] == 4


def test_engine_wall_stats_deterministic_under_fake_clock():
    """Two identical runs under identical fake clocks report *identical*
    wall summaries — the wall-domain extraction is a pure function of
    the clock readings, with every lifecycle inequality exact."""
    traffic = [(6, 4, 0), (9, 3, 1), (5, 5, 3), (4, 2, 3)]

    def run():
        eng = Engine(SMOKE, _params(), slots=2, max_len=64,
                     clock=_FakeClock())
        for rid, (p, n, a) in enumerate(traffic):
            eng.submit(_req(rid, p, n, a))
        eng.run()
        return eng

    w1 = run().stats()["wall"]
    w2 = run().stats()["wall"]
    assert w1 == w2
    assert w1["requests"] == len(traffic)
    for metric in ("ttft", "tpot", "e2e", "queue_delay"):
        for q in ("p50", "p95", "p99"):
            assert w1[metric][q] >= 0.0
    assert w1["ttft"]["p50"] > 0.0
    assert w1["e2e"]["p50"] >= w1["ttft"]["p50"]


def test_engine_sim_parity_single_request():
    params = _params()
    eng = Engine(SMOKE, params, slots=1, max_len=64)
    eng.submit(_req(0, 5, 1))
    eng.run()
    res = simulate_serve(SMOKE, [_sreq(0, 5, 1)], slots=1)
    assert_serve_parity(eng.stats(), res.metrics)
    assert eng.stats()["tpot"]["max"] == 0.0


def test_engine_stats_zero_requests_well_defined():
    eng = Engine(SMOKE, _params(), slots=2, max_len=64)
    for stats in (eng.stats(), (eng.run(), eng.stats())[1]):
        assert stats["steps"] == 0
        assert stats["requests"] == 0 and stats["tokens"] == 0
        assert stats["decode_steps"] == {}
        for metric in metrics.SPAN_METRICS:
            assert stats[metric]["count"] == 0.0
        assert stats["wall"]["requests"] == 0
        json.dumps(stats)


# ---------------------------------------------------------------------------
# spans_from_steps on hand-built step records
# ---------------------------------------------------------------------------

class _Rec:
    def __init__(self, step, admitted=(), decoded=()):
        self.step, self.admitted, self.decoded = step, admitted, decoded


def test_spans_from_steps_with_idle_gap_and_arrivals():
    steps = [_Rec(0, admitted=(0,)), _Rec(1, decoded=(0,)),
             # idle gap: scheduler jumps 2..4
             _Rec(5, admitted=(1,)), _Rec(6, decoded=(1,)),
             _Rec(7, decoded=(1,))]
    spans = spans_from_steps(steps, arrivals={0: 0, 1: 3})
    by = {s.rid: s for s in spans}
    assert by[0].finish == 2.0 and by[0].tokens == 2
    assert by[1].queue_delay == 2.0            # arrived 3, admitted 5
    assert by[1].ttft == 1.0
    assert by[1].tpot == 1.0 and by[1].tokens == 3
    s = summarize_spans(spans)
    assert s["requests"] == 2 and s["tokens"] == 5


# ---------------------------------------------------------------------------
# benchmark artifact metadata (satellite: schema_version + provenance)
# ---------------------------------------------------------------------------

def test_run_metadata_schema_version():
    import sys
    sys.path.insert(0, ".")                    # repo root for benchmarks/
    from benchmarks import common
    meta = common.run_metadata()
    assert meta["schema_version"] == common.REPORT_SCHEMA_VERSION == 4
    assert meta["python"] and meta["jax"]
    assert isinstance(meta["git"], str) and meta["git"]


def test_obs_cli_rewrite_stall(capsys):
    from repro.obs.__main__ import main
    assert main(["--rewrite-stall"]) == 0
    out = capsys.readouterr().out
    assert "57.1%" in out and "critical: ATTN" in out


def test_obs_cli_perfetto_roundtrip(tmp_path, capsys):
    from repro.obs.__main__ import main
    out = tmp_path / "stall.perfetto.json"
    assert main(["--rewrite-stall", "--ping-pong",
                 "--perfetto", str(out)]) == 0
    tl = timeline.load_timeline(str(out))
    assert timeline.validate_timeline(tl)["events"] > 0
    capsys.readouterr()                        # drain the text report
    assert main(["--rewrite-stall", "--json"]) == 0
    rep = json.loads(capsys.readouterr().out)
    assert rep["rewrite_stall_fraction"] == pytest.approx(4 / 7)
