import jax
import pytest

# Tests run on the single CPU device (the dry-run, and only the dry-run,
# forces 512 host devices — launch/dryrun.py sets XLA_FLAGS first).
jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rngs():
    return jax.random.split(jax.random.PRNGKey(0), 16)
