"""repro.sim — cycle-approximate StreamDCIM simulator tests.

Covers the ISSUE-1 acceptance criteria: baseline orderings, the §I
rewrite-stall fractions, and the cross-check that simulated per-mode HBM
traffic agrees with the analytic model ``streamed_bytes_per_layer``.
"""
import math

import pytest

from repro.configs import registry
from repro.core.streaming import (streamed_bytes_per_layer,
                                  tile_stream_profitable)
from repro.core.types import ExecutionMode
from repro.sim import (STREAMDCIM_BASE, STREAMDCIM_WIDEBUS, MacroArray,
                       MacroMode, build_workload, compare_modes,
                       simulate_rewrite_stall)
from repro.sim.workload import BLOCK, AttnOp, GemmOp

EM = ExecutionMode


# ---------------------------------------------------------------- macro model

def test_macro_rewrite_latency_matches_si_arithmetic():
    """K = 2048x512 INT8 over a 512-bit bus: n*d/64 = 16384 cycles."""
    arr = MacroArray(STREAMDCIM_BASE, STREAMDCIM_BASE.num_groups)
    assert arr.rewrite_cycles(2048 * 512) == 2048 * 512 // 64


def test_macro_modes_trade_capacity_for_overlap():
    hw = STREAMDCIM_BASE
    normal = MacroArray(hw, 2, MacroMode.NORMAL)
    hybrid = MacroArray(hw, 2, MacroMode.HYBRID)
    assert normal.capacity_tiles == 2 * hybrid.capacity_tiles
    assert hybrid.overlap_rewrite and not normal.overlap_rewrite


def test_gemm_cycles_scale_with_passes():
    arr = MacroArray(STREAMDCIM_BASE, STREAMDCIM_BASE.num_groups)
    one_pass = arr.gemm_cycles(1024, 128, 128)
    assert one_pass == 1024 * STREAMDCIM_BASE.vector_cycles
    # 4x the stationary tiles of the capacity -> 2 passes with cap 128.
    assert arr.gemm_cycles(1024, 512, 8192) == 2 * one_pass


# ------------------------------------------------------------ §I stall repro

def test_si_rewrite_stall_fraction_near_57_percent():
    st = simulate_rewrite_stall(STREAMDCIM_BASE)
    assert abs(st["rewrite_frac"] - 0.57) < 0.05     # paper §I: "over 57%"


def test_ping_pong_hides_rewrite_stall():
    serial = simulate_rewrite_stall(STREAMDCIM_BASE, iters=8)
    pp = simulate_rewrite_stall(STREAMDCIM_BASE, ping_pong=True, iters=8)
    assert pp["cycles_per_phase"] < serial["cycles_per_phase"]
    assert pp["exposed_stall_frac"] < serial["exposed_stall_frac"]
    # With a wide-enough rewrite bus the stall disappears almost entirely.
    wide = simulate_rewrite_stall(STREAMDCIM_WIDEBUS, ping_pong=True,
                                  iters=8)
    assert wide["exposed_stall_frac"] < 0.10


# ------------------------------------------------------------------ workloads

def test_vilbert_workload_structure():
    cfg = registry.get_config("vilbert-base")
    wl = build_workload(cfg)
    assert len(wl.layers) == cfg.num_layers - cfg.num_coattn_layers \
        + cfg.num_coattn_layers
    attn = [op for _, op in wl.attention_ops]
    crosses = [op for op in attn if op.cross]
    # One cross-attention per stream per co-TRM block.
    assert len(crosses) == 2 * cfg.num_coattn_layers
    # Cross-forwarding: K/V sourced from the *other* modality's width.
    x_co = next(op for op in crosses if op.name.startswith("cox"))
    assert x_co.d_q == cfg.d_model and x_co.d_kv == cfg.d_model_y


def test_attention_free_archs_rejected_clearly():
    with pytest.raises(ValueError, match="attention-free"):
        build_workload(registry.get_config("mamba2-780m"))


def test_workload_sequences_are_block_aligned():
    for arch in registry.SIM_ARCHS:
        wl = build_workload(registry.get_config(arch))
        for _, op in wl.attention_ops:
            assert op.seq_q % BLOCK == 0 and op.seq_kv % BLOCK == 0, arch


# ----------------------------------------------------- three-way comparison

@pytest.fixture(scope="module")
def vilbert_results():
    return compare_modes(registry.get_config("vilbert-base"),
                         STREAMDCIM_BASE)


def test_scheduler_ordering(vilbert_results):
    """The paper's headline ordering: StreamDCIM < layer-based < non-str."""
    tile = vilbert_results[EM.TILE_STREAM].cycles
    layer = vilbert_results[EM.LAYER_STREAM].cycles
    non = vilbert_results[EM.NON_STREAM].cycles
    assert tile < layer < non
    assert non / tile >= 2.0         # acceptance floor (paper: 2.63x geo)
    assert layer / tile >= 1.1       # acceptance floor (paper: 1.28x geo)


def test_dma_bytes_match_analytic_model(vilbert_results):
    """Simulated per-mode HBM bytes for one co-attention op agree with
    ``streamed_bytes_per_layer`` within 10%."""
    cfg = registry.get_config("vilbert-base")
    wl = build_workload(cfg)
    li, op = next((li, op) for li, op in wl.attention_ops
                  if op.name == "cox0_co")
    for mode, res in vilbert_results.items():
        sim_bytes = res.op_dma_bytes(op.name)
        ana = streamed_bytes_per_layer(
            op.seq_q, op.seq_kv, op.d_kv, op.heads, op.kv_heads,
            op.head_dim, mode, block_q=BLOCK,
            bytes_per_el=STREAMDCIM_BASE.act_bytes)
        assert sim_bytes == pytest.approx(ana, rel=0.10), mode


def test_total_hbm_ordering_tracks_modes(vilbert_results):
    """TILE_STREAM moves the least HBM traffic on MHA models."""
    assert (vilbert_results[EM.TILE_STREAM].hbm_bytes
            < vilbert_results[EM.LAYER_STREAM].hbm_bytes
            < vilbert_results[EM.NON_STREAM].hbm_bytes)


def test_gqa_fallback_agrees_with_profitability_rule():
    """For aggressively-GQA models the analytic rule says tile-streaming
    is traffic-negative; the simulator independently reproduces that
    (more DMA and no cycle win) — cross-validating choose_mode."""
    cfg = registry.get_config("qwen2-vl-2b")
    assert not tile_stream_profitable(cfg.d_model, cfg.num_kv_heads,
                                      cfg.head_dim)
    res = compare_modes(cfg, STREAMDCIM_BASE)
    assert res[EM.TILE_STREAM].hbm_bytes > res[EM.LAYER_STREAM].hbm_bytes
    assert res[EM.TILE_STREAM].cycles > res[EM.LAYER_STREAM].cycles


def test_layer_cycles_partition_makespan(vilbert_results):
    for res in vilbert_results.values():
        assert sum(res.layer_cycles) == res.cycles
        assert all(c > 0 for c in res.layer_cycles)


def test_trace_utilization_bounded(vilbert_results):
    tr = vilbert_results[EM.TILE_STREAM].trace
    for resource in ("GEN", "ATTN", "BUS", "HBM", "NOC"):
        u = tr.utilization(resource)
        assert 0.0 < u <= 1.0, resource


def test_rewrite_stall_exposed_only_without_ping_pong(vilbert_results):
    """LAYER_STREAM rewrites on the macro array (stall); TILE_STREAM's
    rewrites ride the shadow-array bus and never occupy ATTN."""
    layer_tr = vilbert_results[EM.LAYER_STREAM].trace
    tile_tr = vilbert_results[EM.TILE_STREAM].trace
    assert any(e.kind == "rewrite" and e.resource == "ATTN"
               for e in layer_tr.events)
    assert all(e.resource == "BUS" for e in tile_tr.events
               if e.kind == "rewrite")
