"""repro.dse — design-space exploration tests (ISSUE-3 acceptance).

Pins the sweep-row contract (latency/energy/EDP/utilization/plan per
row), Pareto and knee extraction, the HardwareConfig.sweep validation
path, the base-not-dominated-by-small acceptance criterion, and the
replay guarantee: a frontier row's serialized plan re-simulated through
``simulate_plan`` reproduces its latency and energy exactly.
"""
import json

import pytest

from repro.configs import registry
from repro.configs.hardware import (HW_PRESETS, HardwareConfig,
                                    STREAMDCIM_BASE)
from repro.dse import (Axes, SweepRow, dominates, grid_points,
                       pareto_frontier, run_sweep, simulate_point,
                       utilization_knee)
from repro.plan.planner import ExecutionPlan
from repro.sim import simulate_plan

SEQ = 1024          # short sequences keep the swept points fast

SMALL_AXES = Axes(groups=((2, 1), (4, 2), (8, 4)),
                  rewrite_bus_bits=(512,), ping_pong=(True,))


@pytest.fixture(scope="module")
def sweep():
    return run_sweep(models=["vilbert-base", "whisper-base"],
                     axes=SMALL_AXES, seq_lens=(SEQ,),
                     include_presets=False)


# -------------------------------------------------------- sweep construction

def test_sweep_constructor_validates_like_post_init():
    with pytest.raises(ValueError, match="gen_groups"):
        HardwareConfig.sweep(num_groups=2, gen_groups=3)
    with pytest.raises(ValueError, match="multiple of 8"):
        HardwareConfig.sweep(rewrite_bus_bits=100)
    with pytest.raises(ValueError, match="num_groups must be > 0"):
        HardwareConfig.sweep(num_groups=0, gen_groups=0)
    with pytest.raises(ValueError, match="unknown"):
        HardwareConfig.sweep(nmu_groups=8)


def test_sweep_constructor_derives_deterministic_names():
    hw = HardwareConfig.sweep(num_groups=8, gen_groups=4,
                              rewrite_bus_bits=1024)
    assert hw.name == "streamdcim-base/g8-gg4-bus1024"
    # overrides equal to the base are elided from the name
    assert HardwareConfig.sweep(ping_pong=True).name == "streamdcim-base"
    assert HardwareConfig.sweep(ping_pong=False).name == "streamdcim-base/pp0"


def test_grid_points_presets_first_and_deduped():
    import dataclasses

    points, skipped = grid_points(presets=tuple(HW_PRESETS.values()))
    names = [p.name for p in points]
    assert names[:3] == list(HW_PRESETS)

    # the (4,2,512,pp) grid combo IS streamdcim-base: deduped, not repeated
    def params(p):
        d = dataclasses.asdict(p)
        d.pop("name")
        return tuple(sorted(d.items()))
    assert len({params(p) for p in points}) == len(points)
    assert not skipped


def test_extra_axes_reject_builtin_collisions():
    with pytest.raises(ValueError, match="collide"):
        Axes(groups=((8, 4),), extra={"num_groups": (2,)})
    # genuinely extra fields pass through to the grid
    axes = Axes(groups=((4, 2),), rewrite_bus_bits=(512,),
                ping_pong=(True,), extra={"macros_per_group": (8, 16)})
    assert [ov["macros_per_group"] for ov in axes.overrides()] == [8, 16]


def test_grid_points_skip_invalid_combos_with_reason():
    axes = Axes(groups=((2, 1), (2, 2)), rewrite_bus_bits=(512,),
                ping_pong=(True,))
    points, skipped = grid_points(axes=axes)
    assert len(points) == 1 and len(skipped) == 1
    assert "gen_groups" in skipped[0]["reason"]


# ------------------------------------------------------------- sweep rows

def test_sweep_rows_carry_full_record(sweep):
    assert len(sweep.rows) == 2 * 3          # 2 models x 3 design points
    for row in sweep.rows:
        assert row.latency_cycles > 0
        assert row.energy_pj > 0
        assert row.edp == pytest.approx(row.energy_pj * row.latency_cycles)
        assert 0.0 < row.utilization["ATTN"] <= 1.0
        assert sum(row.energy_by_resource.values()) == pytest.approx(
            row.energy_pj)
        plan = ExecutionPlan.from_json(row.plan_json)
        assert plan.model == row.model
        d = row.to_dict()
        json.dumps(d)                        # artifact must be serializable
        assert d["num_macros"] == row.num_macros


def test_pareto_frontier_nonempty_and_nondominated(sweep):
    for model in sweep.models():
        frontier = sweep.pareto(model)
        rows = sweep.rows_for(model)
        assert frontier
        for f in frontier:
            assert not any(dominates(r, f) for r in rows)
        # every non-frontier row is dominated by some frontier row
        for r in rows:
            if r not in frontier:
                assert any(dominates(f, r) for f in frontier)


def test_utilization_knee_definition(sweep):
    rows = sweep.rows_for("vilbert-base")
    knee = utilization_knee(rows, tolerance=0.10)
    best = min(r.latency_cycles for r in rows)
    assert knee.latency_cycles <= 1.10 * best
    # no smaller design point is also within tolerance
    for r in rows:
        if r.num_macros < knee.num_macros:
            assert r.latency_cycles > 1.10 * best
    assert utilization_knee([]) is None
    # infinite tolerance admits everything -> smallest array wins
    loose = utilization_knee(rows, tolerance=float("inf"))
    assert loose.num_macros == min(r.num_macros for r in rows)


def test_frontier_row_replays_exactly(sweep):
    """Acceptance: plan_json -> from_json -> simulate_plan reproduces the
    frontier row's latency and energy bit-for-bit."""
    row = sweep.pareto("vilbert-base")[0]
    plan = ExecutionPlan.from_json(row.plan_json)
    res = simulate_plan(plan)                # hw rebuilt from plan.hw_params
    rep = res.energy(registry.get_energy_model(row.energy_model))
    assert res.cycles == row.latency_cycles
    assert rep.total_pj == row.energy_pj
    assert rep.edp == row.edp
    assert res.hbm_bytes == row.hbm_bytes


def test_base_not_energy_dominated_by_small_at_vilbert_shapes():
    """Acceptance: the paper's design point is on the base-vs-small
    trade-off curve, not strictly worse, at ViLBERT-base shapes."""
    cfg = registry.get_config("vilbert-base")
    base = simulate_point(cfg, HW_PRESETS["streamdcim-base"])
    small = simulate_point(cfg, HW_PRESETS["streamdcim-small"])
    assert not dominates(small, base)
    # the reason: half the macro array simulates strictly slower
    assert small.latency_cycles > base.latency_cycles


def test_multi_shape_sweeps_never_mix_shapes():
    """Frontier and knee partition by (model, seq_len): the same design
    point at a shorter sequence must not 'dominate' its longer twin."""
    res = run_sweep(models=["whisper-base"],
                    axes=Axes(groups=((4, 2),), rewrite_bus_bits=(512,),
                              ping_pong=(True,)),
                    seq_lens=(256, 1024), include_presets=False)
    assert res.groups() == [("whisper-base", 256), ("whisper-base", 1024)]
    # one design point per shape -> trivially on its shape's frontier
    for seq in (256, 1024):
        front = res.pareto("whisper-base", seq)
        assert len(front) == 1 and front[0].seq_len == seq
    # pareto(model) concatenates both shape frontiers, no cross-dominance
    assert {r.seq_len for r in res.pareto("whisper-base")} == {256, 1024}
    knees = res.knees()
    assert set(knees) == {"whisper-base@seq256", "whisper-base@seq1024"}
    assert knees["whisper-base@seq1024"].seq_len == 1024
    ids = res.to_dict()["pareto"]
    assert set(ids) == set(knees) and all(ids.values())


def test_single_shape_sweep_keeps_bare_model_label(sweep):
    assert sweep.label("vilbert-base", SEQ) == "vilbert-base"
    assert set(sweep.knees()) == {"vilbert-base", "whisper-base"}


def test_points_budget_keeps_presets_first():
    res = run_sweep(models=["whisper-base"], points=2, seq_lens=(SEQ,))
    assert [r.hw for r in res.rows] == ["streamdcim-base",
                                        "streamdcim-small"]


def test_pareto_frontier_helper_on_synthetic_rows():
    def row(lat, pj):
        return SweepRow(model="m", seq_len=0, hw=f"hw{lat}",
                        hw_params={"num_groups": 4, "macros_per_group": 16},
                        energy_model="e", latency_cycles=lat, hbm_bytes=0,
                        energy_pj=pj, edp=lat * pj, utilization={},
                        energy_by_resource={}, plan_json="{}")
    a, b, c, d = row(10, 50.0), row(20, 20.0), row(30, 30.0), row(10, 60.0)
    front = pareto_frontier([a, b, c, d])
    assert [(r.latency_cycles, r.energy_pj) for r in front] == [(10, 50.0),
                                                                (20, 20.0)]
    # exact ties on both metrics are mutually non-dominated: all kept
    t1, t2, e = row(100, 5.0), row(100, 5.0), row(200, 3.0)
    front = pareto_frontier([t1, t2, e])
    assert len(front) == 3
    for f in front:
        assert not any(dominates(r, f) for r in (t1, t2, e))
    # ...but a same-energy/slower row is dominated, not a tie
    assert len(pareto_frontier([row(100, 5.0), row(110, 5.0)])) == 1


# ---------------------------------------------------------------------------
# The energy-model axis (ROADMAP: ENERGY_CONFIGS x HW grid)
# ---------------------------------------------------------------------------

def test_energy_axis_partitions_cells():
    ems = [registry.ENERGY_CONFIGS["streamdcim-energy-base"],
           registry.ENERGY_CONFIGS["streamdcim-energy-dramheavy"]]
    res = run_sweep(models=["whisper-base"], points=3, seq_lens=(SEQ,),
                    energy_models=ems)
    assert res.energy_models() == [e.name for e in ems]
    assert len(res.rows) == 3 * 2           # one row per (point, table)
    # latency is cost-table-invariant (same simulation, re-folded energy)
    by_hw = {}
    for r in res.rows:
        by_hw.setdefault(r.hw, []).append(r)
    for rows in by_hw.values():
        assert len({r.latency_cycles for r in rows}) == 1
        assert len({r.energy_pj for r in rows}) == 2  # tables DO differ
    # frontier extraction never mixes cost tables
    for em in res.energy_models():
        assert all(r.energy_model == em
                   for r in res.pareto(energy_model=em))
    labels = set(res.knees())
    assert any(l.endswith("/streamdcim-energy-dramheavy") for l in labels)


def test_energy_axis_frontier_sensitivity_report():
    ems = list(registry.ENERGY_CONFIGS.values())
    res = run_sweep(models=["whisper-base"], points=4, seq_lens=(SEQ,),
                    energy_models=ems)
    sens = res.frontier_sensitivity()
    assert set(sens) == {"whisper-base"}
    rec = sens["whisper-base"]
    assert rec["base"] == ems[0].name
    assert set(rec["frontier_hw"]) == {e.name for e in ems}
    for em, j in rec["jaccard_vs_base"].items():
        assert 0.0 <= j <= 1.0
    assert rec["jaccard_vs_base"][ems[0].name] == 1.0
    for hw in rec["stable_hw"]:
        for front in rec["frontier_hw"].values():
            assert hw in front
    d = res.to_dict()
    assert d["frontier_sensitivity"]["whisper-base"]["base"] == ems[0].name
    assert d["energy_models"] == [e.name for e in ems]


def test_single_energy_model_sweep_unchanged():
    res = run_sweep(models=["whisper-base"], points=2, seq_lens=(SEQ,))
    assert res.frontier_sensitivity() == {}
    assert res.energy_models() == [res.energy_model]
    # labels carry no energy suffix when only one table swept
    assert set(res.knees()) == {"whisper-base"}
