"""int8 projection path (the paper's INT16-CIM precision knob)."""
import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, st

from repro.core import runtime
from repro.kernels import ops
from repro.kernels.quant import int8_matmul, quantize_cols, quantize_rows

KEYS = jax.random.split(jax.random.PRNGKey(21), 4)


def test_int8_matmul_close_to_f32():
    x = jax.random.normal(KEYS[0], (64, 128)) * 0.5
    w = jax.random.normal(KEYS[1], (128, 96)) * 0.1
    ref = x @ w
    q = int8_matmul(x, w)
    err = jnp.abs(q - ref).max() / (jnp.abs(ref).max() + 1e-9)
    assert float(err) < 0.03, float(err)


def test_projection_flag_routes_int8():
    x = jax.random.normal(KEYS[2], (4, 32, 64)) * 0.5
    w = jax.random.normal(KEYS[3], (64, 48)) * 0.1
    base = ops.projection(x, w)
    with runtime.flags(quantize_proj=True):
        q = ops.projection(x, w)
    assert q.shape == base.shape
    rel = jnp.abs(q - base).max() / (jnp.abs(base).max() + 1e-9)
    assert 0 < float(rel) < 0.05   # differs (quantized) but close


@given(m=st.integers(1, 32), k=st.integers(8, 64), n=st.integers(1, 32))
@settings(max_examples=15, deadline=None)
def test_quantize_roundtrip_bounds(m, k, n):
    x = jax.random.normal(jax.random.PRNGKey(m * 1000 + k), (m, k))
    q, s = quantize_rows(x)
    deq = q.astype(jnp.float32) * s
    assert float(jnp.abs(deq - x).max()) <= float(s.max()) / 2 + 1e-6
    w = jax.random.normal(jax.random.PRNGKey(n), (k, n))
    qc, sc = quantize_cols(w)
    deqc = qc.astype(jnp.float32) * sc
    assert float(jnp.abs(deqc - w).max()) <= float(sc.max()) / 2 + 1e-6
