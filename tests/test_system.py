"""End-to-end behaviour tests: training convergence, checkpoint/restart
fault tolerance, decode==forward consistency, serving engine, data
determinism."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core import runtime
from repro.core.types import ExecutionMode, ShapeConfig
from repro.data.pipeline import SyntheticLM, TextCorpus
from repro.launch.mesh import make_host_mesh
from repro.serve.engine import Engine, Request
from repro.train import loop as L
from repro.train import optimizer as OPT
from repro.train.checkpoint import Checkpointer

SHAPE = ShapeConfig("sys", seq_len=64, global_batch=4, kind="train")


def _train(cfg, steps, ckpt_dir=None, seed=0, log_every=None):
    mesh = make_host_mesh()
    src = SyntheticLM(cfg, SHAPE, seed=seed)
    tcfg = L.TrainConfig(steps=steps,
                         log_every=log_every or max(steps // 2, 1),
                         checkpoint_every=max(steps // 2, 1),
                         checkpoint_dir=ckpt_dir,
                         opt=OPT.OptimizerConfig(learning_rate=1e-3,
                                                 warmup_steps=5,
                                                 decay_steps=200))
    return L.train(cfg, SHAPE, src, mesh, tcfg)


def test_training_reduces_loss():
    cfg = registry.get_config("qwen3-32b", smoke=True)
    out = _train(cfg, steps=30, log_every=2)
    hist = out["metrics"]
    # initial CE ~= ln(vocab) ~ 6.2; training must pull it well below
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.3, hist


def test_checkpoint_restart_exact_resume():
    """Fault tolerance: kill at step 10, restart, end state must equal an
    uninterrupted 20-step run (deterministic data + exact state restore)."""
    cfg = registry.get_config("starcoder2-7b", smoke=True)
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        full = _train(cfg, steps=20, ckpt_dir=d1)
        _train(cfg, steps=10, ckpt_dir=d2)          # "crashes" after 10
        resumed = _train(cfg, steps=20, ckpt_dir=d2)  # restart -> 20
        for a, b in zip(jax.tree.leaves(full["params"]),
                        jax.tree.leaves(resumed["params"])):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=2e-5, rtol=2e-5)


def test_checkpoint_elastic_reshard_roundtrip():
    """Save, then restore with explicit shardings on the (1,1) host mesh —
    the reshard-on-restore path used for elastic scaling."""
    cfg = registry.get_config("qwen3-32b", smoke=True)
    mod = registry.model_module(cfg)
    params = mod.init(jax.random.PRNGKey(0), cfg)
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(7, {"params": params})
        from repro.distributed import sharding as SH
        mesh = make_host_mesh()
        shardings = SH.param_shardings(
            jax.eval_shape(lambda: params), cfg, mesh)
        restored = ck.restore(7, {"params": params},
                              {"params": shardings})
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(restored["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity_partial_write_ignored():
    cfg = registry.get_config("mamba2-780m", smoke=True)
    mod = registry.model_module(cfg)
    params = mod.init(jax.random.PRNGKey(0), cfg)
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(5, {"p": params})
        # simulate a crashed write
        os.makedirs(os.path.join(d, "step_00000009.tmp"))
        assert ck.latest_step() == 5


def test_data_pipeline_deterministic_and_resumable():
    cfg = registry.get_config("qwen3-32b", smoke=True)
    a = SyntheticLM(cfg, SHAPE, seed=3)
    b = SyntheticLM(cfg, SHAPE, seed=3)
    for step in (0, 5, 17):
        x, y = a.batch(step), b.batch(step)
        np.testing.assert_array_equal(x["tokens"], y["tokens"])
    assert not np.array_equal(a.batch(0)["tokens"], a.batch(1)["tokens"])


def test_text_corpus_packs_and_shifts(tmp_path):
    p = tmp_path / "corpus.txt"
    p.write_text("hello world, this is a tiny corpus for packing tests. " * 50)
    cfg = registry.get_config("qwen3-32b", smoke=True)
    src = TextCorpus(cfg, SHAPE, str(p))
    b = src.batch(0)
    assert b["tokens"].shape == (4, 64)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_serving_engine_greedy_matches_forward():
    """Engine decode tokens must equal argmax over the teacher-forced
    forward logits when re-fed (greedy self-consistency)."""
    cfg = registry.get_config("starcoder2-7b", smoke=True)
    mod = registry.model_module(cfg)
    with runtime.flags(moe_capacity=100.0):
        params = mod.init(jax.random.PRNGKey(0), cfg)
        eng = Engine(cfg, params, slots=2, max_len=64)
        prompts = [np.arange(5, 13, dtype=np.int32),
                   np.arange(40, 52, dtype=np.int32)]
        for i, pr in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=pr, max_new_tokens=6))
        done = eng.run()
    assert len(done) == 2
    for r in done:
        assert len(r.out_tokens) == 6
        assert all(0 <= t < cfg.vocab_size for t in r.out_tokens)


def test_grad_accumulation_matches_full_batch():
    """microbatched train step == full-batch step (same grads modulo fp)."""
    from repro.train import steps as ST
    cfg = registry.get_config("qwen3-32b", smoke=True)
    mod = registry.model_module(cfg)
    src = SyntheticLM(cfg, SHAPE, seed=4)
    batch = jax.tree.map(jnp.asarray, src.batch(0))
    params = mod.init(jax.random.PRNGKey(0), cfg)
    ocfg = OPT.OptimizerConfig(learning_rate=1e-3, warmup_steps=1)
    s1 = ST.make_train_step(cfg, ocfg, microbatches=1)
    s2 = ST.make_train_step(cfg, ocfg, microbatches=2)
    p1, _, m1 = s1(params, OPT.init(params), batch)
    p2, _, m2 = s2(params, OPT.init(params), batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-4, rtol=5e-4)


def test_int8_error_feedback_unbiased():
    from repro.distributed.compression import ErrorFeedback
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 64)) * 0.01}
    resid = ErrorFeedback.init(g)
    total_q = jnp.zeros((64, 64))
    steps = 50
    for _ in range(steps):
        q, resid = ErrorFeedback.apply(g, resid)
        total_q = total_q + q["w"]
    # time-averaged quantized gradient converges to the true gradient
    np.testing.assert_allclose(np.asarray(total_q / steps),
                               np.asarray(g["w"]), atol=2e-4)
