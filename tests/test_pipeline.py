"""Ring collective-matmul overlap — correctness + lowering shape."""
import os
import subprocess
import sys
import textwrap

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np, re
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.pipeline import gather_matmul_overlapped

    mesh = jax.make_mesh((4,), ("model",))
    M, K, N = 64, 32, 48
    x = jax.random.normal(jax.random.PRNGKey(0), (M, K))
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N)) * 0.1
    xs = jax.device_put(x, NamedSharding(mesh, P("model", None)))
    out = jax.jit(lambda x, w: gather_matmul_overlapped(x, w, mesh))(xs, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x @ w),
                               atol=1e-4, rtol=1e-4)
    text = jax.jit(lambda x, w: gather_matmul_overlapped(x, w, mesh)) \
        .lower(xs, w).compile().as_text()
    # the ring lowers to collective-permutes, NOT one big all-gather of x
    assert text.count("collective-permute") >= 1, "no ring permutes found"
    print("OK")
""")


def test_ring_matmul_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=300,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
