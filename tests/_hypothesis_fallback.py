"""Minimal stand-in for hypothesis when it isn't installed.

Tier-1 environments may lack ``hypothesis``; rather than skipping the
property tests entirely, this shim runs each ``@given`` test over a small
deterministic grid (lo / mid / hi per strategy).  Only the subset of the
API these tests use is provided: ``given`` with keyword strategies,
``settings``, ``st.integers``, ``st.floats``.
"""
from __future__ import annotations


import itertools


class _Strategy:
    def __init__(self, samples):
        self._samples = samples

    def samples(self):
        return self._samples


class st:  # noqa: N801 — mirrors ``hypothesis.strategies as st``
    @staticmethod
    def integers(min_value, max_value):
        mid = (min_value + max_value) // 2
        return _Strategy(sorted({min_value, mid, max_value}))

    @staticmethod
    def floats(min_value, max_value):
        mid = (min_value + max_value) / 2.0
        return _Strategy(sorted({min_value, mid, max_value}))


def given(**strategies):
    names = list(strategies)

    def deco(fn):
        # No functools.wraps: pytest must see a zero-arg signature, not the
        # original parameters (it would look for fixtures named after them).
        def wrapper():
            grids = [strategies[n].samples() for n in names]
            for combo in itertools.product(*grids):
                fn(**dict(zip(names, combo)))
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco


def settings(**_kwargs):
    return lambda fn: fn
