"""Validation of the while-trip-aware HLO analyzer (launch/hlo_analysis.py)
— the §Roofline methodology.  Ground truths are hand-computed FLOPs."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_analysis as HA


def _analyze(fn, *args, devices=1):
    comp = jax.jit(fn).lower(*args).compile()
    return HA.analyze(comp.as_text(), total_devices=devices,
                      multi_pod=False)


def test_plain_matmul_chain_exact():
    a = jnp.zeros((256, 512))
    b = jnp.zeros((512, 128))
    c = jnp.zeros((128, 64))
    r = _analyze(lambda a, b, c: (a @ b) @ c, a, b, c)
    assert r["flops"] == 2 * 256 * 512 * 128 + 2 * 256 * 128 * 64


def test_scan_multiplies_by_trip_count():
    def g(x, w):
        def step(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(step, x, None, length=10)
        return y

    x = jnp.zeros((128, 256))
    w = jnp.zeros((256, 256))
    r = _analyze(g, x, w)
    assert r["flops"] == 10 * 2 * 128 * 256 * 256


def test_scan_remat_microbatch_exact():
    """The exact structure of a train step: mb scan over value_and_grad of
    a rematted layer scan.  fwd + remat-fwd + dx + dw = 4 matmul passes."""
    L, B, S, D, MB = 4, 8, 32, 64, 2

    def layer(x, w):
        return jnp.tanh(x @ w)

    def loss(ws, xb):
        def step(c, w):
            return jax.checkpoint(layer)(c, w), None
        y, _ = jax.lax.scan(step, xb, ws)
        return jnp.mean(y ** 2)

    def train(ws, xs):
        def mb_step(acc, xb):
            l, g = jax.value_and_grad(loss)(ws, xb)
            return jax.tree.map(jnp.add, acc, g), l
        g0 = jax.tree.map(jnp.zeros_like, ws)
        g, ls = jax.lax.scan(mb_step, g0, xs)
        return g, ls.mean()

    ws = jnp.zeros((L, D, D))
    xs = jnp.zeros((MB, B, S, D))
    r = _analyze(train, ws, xs)
    expect = MB * L * (2 * B * S * D * D) * 4
    assert abs(r["flops"] - expect) / expect < 1e-6
    # XLA's own cost analysis must be a large undercount here (the reason
    # this analyzer exists)
    ca = jax.jit(train).lower(ws, xs).compile().cost_analysis()
    if isinstance(ca, (list, tuple)):   # jax 0.4.x: one dict per partition
        ca = ca[0]
    assert ca["flops"] < 0.3 * expect


def test_scanned_equals_unrolled_model():
    """Same computation scanned vs python-unrolled must analyze equal."""
    from repro.core import runtime

    def layer(x, w):
        return jnp.tanh(x @ w)

    def f_scan(x, ws):
        def step(c, w):
            return layer(c, w), None
        y, _ = jax.lax.scan(step, x, ws)
        return jnp.sum(y)

    def f_unrolled(x, ws):
        c = x
        for i in range(ws.shape[0]):
            c = layer(c, ws[i])
        return jnp.sum(c)

    x = jnp.zeros((64, 128))
    ws = jnp.zeros((6, 128, 128))
    r1 = _analyze(f_scan, x, ws)
    r2 = _analyze(f_unrolled, x, ws)
    assert r1["flops"] == r2["flops"]


def test_sharded_collective_traffic_exact():
    import os
    if jax.device_count() < 2:
        pytest.skip("needs >1 host device (dry-run only)")


def test_collective_formulas():
    """Ring-traffic arithmetic on synthetic HLO lines."""
    hlo = """
HloModule m, entry_computation_layout={()->f32[]}

ENTRY %main (p: f32[16,16]) -> f32[16,16] {
  %p = f32[16,16]{1,0} parameter(0)
  %ar = f32[16,16]{1,0} all-reduce(%p), replica_groups=[2,4]<=[8], use_global_device_ids=true, to_apply=%add
  ROOT %ag = f32[16,16]{1,0} all-gather(%ar), replica_groups=[4,2]<=[8], dimensions={0}
}
"""
    r = HA.analyze(hlo, total_devices=8, multi_pod=False)
    size = 16 * 16 * 4
    # all-reduce group 4: 2*s*(3/4); all-gather group 2: s*(1/2)
    assert abs(r["ici"] - (2 * size * 3 / 4 + size / 2)) < 1e-6
    assert r["counts"] == {"all-reduce": 1, "all-gather": 1}
