PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: verify test bench bench-sim bench-sim-json dse dse-smoke \
	search-smoke replay-smoke serve-smoke obs-smoke shard-smoke \
	bench-baseline bench-check

# Sections that register perf-tracking snapshots (benchmarks/history.py).
BENCH_SECTIONS := bench_sim serve shard dse

# The dse section's budget/width for the bench-baseline/bench-check
# lane: deterministic metrics (num_rows, frontier_size) depend on the
# budget, so baseline and check MUST use the same flags.
BENCH_DSE_FLAGS := --points 12 --workers 2

# Tier-1 verification (ROADMAP.md).
verify:
	$(PYTHON) -m pytest -x -q

test: verify

bench:
	$(PYTHON) benchmarks/run.py

bench-sim:
	$(PYTHON) benchmarks/run.py bench_sim

# CI smoke: machine-readable report (rows + ExecutionPlan summaries).
bench-sim-json:
	$(PYTHON) benchmarks/run.py bench_sim --json bench_sim.json

# Design-space exploration (DESIGN.md §9): full grid / CI-budgeted smoke.
dse:
	$(PYTHON) benchmarks/run.py dse --json dse_sweep.json --workers 4 \
		--cache .simcache

dse-smoke:
	$(PYTHON) benchmarks/run.py dse --json dse_sweep.json --points 4

# Successive-halving frontier search smoke (DESIGN.md §16): budgeted
# search through the benchmark harness (artifact: survivors' sweep +
# per-rung elimination ledger), then the cache/search invariants —
# warm-vs-cold timing, search-vs-grid frontier equality — in-process.
search-smoke:
	$(PYTHON) benchmarks/run.py dse --search --points 16 --workers 2 \
		--json search_report.json
	$(PYTHON) benchmarks/search_smoke.py search_report.json

# Plan/trace replay smoke (DESIGN.md §10): record a tiny trace on CPU,
# replay it through the simulator, emit the CalibrationReport artifact.
replay-smoke:
	$(PYTHON) benchmarks/run.py replay --json replay_report.json

# Continuous-batching serving smoke (DESIGN.md §11): staggered-arrival
# trace through the live engine AND simulate_serve; asserts the two agree
# on the step timeline and emits the serving artifact.
serve-smoke:
	$(PYTHON) benchmarks/run.py serve --json serve_report.json

# Observability smoke (DESIGN.md §12): the serving smoke with Perfetto
# timeline export.  bench_serve asserts engine==sim TTFT/TPOT parity;
# run.py validates each timeline before writing; the re-load here proves
# the emitted JSON round-trips (loads, non-empty tracks, monotone
# timestamps), and the §I attribution report renders from the micro-trace.
obs-smoke:
	$(PYTHON) benchmarks/run.py serve --json serve_report.json \
		--perfetto timelines
	$(PYTHON) -c "import glob; \
		from repro.obs.timeline import load_timeline, validate_timeline; \
		files = sorted(glob.glob('timelines/*.perfetto.json')); \
		assert files, 'no timelines emitted'; \
		[print(f, validate_timeline(load_timeline(f))) for f in files]"
	$(PYTHON) -m repro.obs --rewrite-stall --critpath --whatif ping_pong
	$(PYTHON) -m repro.obs --model vilbert-base --smoke \
		--mode layer_stream --critpath --whatif ATTN:2 --whatif HBM:4 \
		--perfetto timelines/critpath.perfetto.json

# Chiplet-mesh scale-out smoke (DESIGN.md §13): the chips x topology
# sweep through plan -> shard -> simulate (byte-exactness asserted on
# every point), Perfetto timelines with per-chip + NoC-link tracks, and
# the 4-chip CLI table on the tiny smoke configs.
shard-smoke:
	$(PYTHON) benchmarks/run.py shard --json shard_report.json \
		--perfetto shard_timelines
	$(PYTHON) -c "import glob; \
		from repro.obs.timeline import load_timeline, validate_timeline; \
		files = sorted(glob.glob('shard_timelines/*.perfetto.json')); \
		assert files, 'no shard timelines emitted'; \
		[print(f, validate_timeline(load_timeline(f))) for f in files]"
	$(PYTHON) -m repro.shard --chips 1,4 --smoke

# Perf-regression tracking (DESIGN.md §14): refresh the committed
# BENCH_<section>.json baselines / compare against them (the CI gate —
# exits 1 on any out-of-band regression).
bench-baseline:
	$(PYTHON) benchmarks/run.py $(BENCH_SECTIONS) $(BENCH_DSE_FLAGS) \
		--baseline benchmarks/baselines

bench-check:
	$(PYTHON) benchmarks/run.py $(BENCH_SECTIONS) $(BENCH_DSE_FLAGS) \
		--json bench_check.json --check-baseline benchmarks/baselines
