PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: verify test bench bench-sim bench-sim-json

# Tier-1 verification (ROADMAP.md).
verify:
	$(PYTHON) -m pytest -x -q

test: verify

bench:
	$(PYTHON) benchmarks/run.py

bench-sim:
	$(PYTHON) benchmarks/run.py bench_sim

# CI smoke: machine-readable report (rows + ExecutionPlan summaries).
bench-sim-json:
	$(PYTHON) benchmarks/run.py bench_sim --json bench_sim.json
