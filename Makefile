PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: verify test bench bench-sim

# Tier-1 verification (ROADMAP.md).
verify:
	$(PYTHON) -m pytest -x -q

test: verify

bench:
	$(PYTHON) benchmarks/run.py

bench-sim:
	$(PYTHON) benchmarks/run.py bench_sim
